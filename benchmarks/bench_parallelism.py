"""Fig 10-left: intra-node (latent) and inter-node (ControlNet deferred
fetch) parallelism speedups — plus the MEASURED sharded-execution study.

Two arms:

* **analytic** (`fig10_*`) — the paper-comparable speedup readouts from
  the latency profiles, unchanged;
* **measured** (`sharded_*`) — real stacked backbone forwards on a
  k-device submesh via :class:`ShardedBackend` at k = 1/2/4, emitting
  ``BENCH_parallelism.json`` with per-k throughput.  Waves of W requests
  are served per trial; arms are jit-warmed up front and trials
  interleave round-robin so host-noise bursts hit every k alike; each
  arm reports its MEDIAN wave time (robust to slow and lucky-fast
  outliers).  On hosts with fewer than 4 devices the study re-executes
  itself in a subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the same
  virtual-device mechanism the mesh parity tests use); on real TPU/GPU
  meshes it runs in-process against the hardware.

  The study runs on the reference attention path (see
  ``bench_overhead.batched_exec_study`` for the rationale: on CPU the
  Pallas kernel's interpret-mode emulation cost would swamp the sharding
  signal being measured).
"""

from __future__ import annotations

import os
import statistics
import subprocess
import sys
import time

from benchmarks.common import emit, run_lego_trace
from benchmarks.emit import write_bench_json
from repro.core import ProfileStore, Scheduler
from repro.core.profiles import GPU_H800
from repro.diffusion import FAMILIES, ModelSet, make_controlnet_workflow
from repro.diffusion.serving import DiffusionBackbone
from repro.sim import generate_trace

PARALLELISM_JSON = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_parallelism.json")


def analytic_study() -> None:
    profiles = ProfileStore(GPU_H800)
    for fam in ("sd3", "sd3.5-large", "flux-schnell", "flux-dev"):
        ms = ModelSet(FAMILIES[fam])
        p = profiles.profile_model(ms.backbone)
        sp = p.speedup(1, 2)
        emit(f"fig10_intra_node[{fam}]", p.infer_time(1, 2) * 1e6,
             f"speedup={sp:.2f}x")
    # inter-node: deferred vs eager ControlNet residuals (2 executors)
    for fam in ("sd3", "flux-dev"):
        lats = {}
        for tag, eager in (("deferred", False), ("eager", True)):
            ms = ModelSet(FAMILIES[fam])
            ms.backbone = DiffusionBackbone(FAMILIES[fam], eager_controlnet=eager)
            wf = make_controlnet_workflow(fam, 1, ms)
            trace = generate_trace([wf.name], rate=0.05, duration=200, cv=1.0,
                                   seed=23)
            # cap intra-node parallelism so the ablation isolates the
            # inter-node (deferred-fetch) mechanism; see EXPERIMENTS.md for
            # the eager+latent-parallel interaction we found
            sys_ = run_lego_trace({wf.name: wf}, trace, 2, slo_scale=None,
                                  admission=False,
                                  scheduler_kwargs={"max_parallelism_cap": 1})
            lats[tag] = sys_.mean_latency()
        emit(f"fig10_inter_node[{fam}]", lats["deferred"] * 1e6,
             f"speedup={lats['eager']/lats['deferred']:.2f}x")


class _ShardArm:
    """One (k) arm: a warm ShardedBackend serving W-request backbone waves
    on a k-device submesh (k=1 runs the plain single-device path)."""

    def __init__(self, k: int, wave: int, backbone, cfg) -> None:
        import jax
        from repro.core import MeshManager, ShardedBackend

        self.k = k
        self.wave = wave
        self.backend = ShardedBackend(MeshManager())
        self.mesh = (self.backend.mesh_manager.submesh(list(range(k)))
                     if k > 1 else None)
        key = jax.random.PRNGKey(11)
        ks = jax.random.split(key, 2 * wave)
        self.kwargs = [{
            "latents": jax.random.normal(
                ks[2 * i], (1, cfg.latent_size, cfg.latent_size,
                            cfg.latent_channels)),
            "prompt_embeds": jax.random.normal(
                ks[2 * i + 1], (1, cfg.text_tokens, cfg.text_dim)),
            "t": 0.4, "guidance": 4.5,
        } for i in range(wave)]
        self.backbone = backbone
        self.waves = []
        self.run_trial()          # jit warm-up (excluded from the medians)
        self.waves.clear()

    def run_trial(self) -> None:
        t0 = time.perf_counter()
        if self.mesh is not None:
            outs, _, _ = self.backend.execute_batch(
                self.backbone, [dict(kw) for kw in self.kwargs],
                mesh=self.mesh)
        else:
            outs, _, _ = self.backend.execute_batch(
                self.backbone, [dict(kw) for kw in self.kwargs])
        self.waves.append(time.perf_counter() - t0)

    @property
    def wave_seconds(self) -> float:
        return statistics.median(self.waves)


def sharded_study(trials: int = 15, wave: int = 8) -> None:
    """Measured sharded-vs-single-device backbone throughput at k=1/2/4."""
    import dataclasses

    import jax

    if jax.device_count() < 4:
        _respawn_sharded_study(trials, wave)
        return
    from repro.nn.layers import set_flash_attention

    # bench-scale architecture: the tier-1 toy backbone finishes a wave in
    # ~2 ms, where per-device dispatch (not compute) decides the ranking;
    # scaling d_model/layers/grid up puts a wave in the hundreds-of-ms
    # regime the sharding is for, while still loading in seconds on CPU
    fam = dataclasses.replace(
        FAMILIES["sd3"],
        toy=dataclasses.replace(FAMILIES["sd3"].toy, d_model=256, n_layers=6,
                                n_heads=8, d_ff=1024, latent_size=32))
    backbone = DiffusionBackbone(fam)
    ks = (1, 2, 4)
    prev_flash = set_flash_attention(False)
    try:
        arms = {k: _ShardArm(k, wave, backbone, fam.toy) for k in ks}
        for _ in range(trials):
            for k in ks:
                arms[k].run_trial()
    finally:
        set_flash_attention(prev_flash)
    rows = []
    for k in ks:
        arm = arms[k]
        rows.append({
            "k": k,
            "wave_seconds": arm.wave_seconds,
            "images_per_s": wave / arm.wave_seconds,
            "speedup_vs_single": arms[1].wave_seconds / arm.wave_seconds,
            "sharded_forwards": len(arm.backend.shard_log),
            "devices": sorted({d for s in arm.backend.shard_log
                               for d in s[3]}),
        })
    for row in rows:
        emit(f"sharded_backbone_k{row['k']}",
             1e6 * row["wave_seconds"] / wave,
             f"{row['images_per_s']:.2f} img/s "
             f"({row['speedup_vs_single']:.2f}x vs k=1, "
             f"devices={row['devices']})")
    mono = all(rows[i + 1]["images_per_s"] >= rows[i]["images_per_s"]
               for i in range(len(rows) - 1))
    write_bench_json("parallelism", rows, path=PARALLELISM_JSON,
                     gates={"throughput_monotone": mono})
    emit("sharded_backbone_monotone", float(mono),
         f"throughput monotone k=1..4: {mono}; wrote {PARALLELISM_JSON}")


def _respawn_sharded_study(trials: int, wave: int) -> None:
    """Too few local devices: rerun this study in a child with 8 forced
    virtual host devices (results land in the same JSON/CSV stream)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(root, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    code = ("from benchmarks.bench_parallelism import sharded_study; "
            f"sharded_study(trials={trials}, wave={wave})")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=root,
                         capture_output=True, text=True, timeout=1800)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        emit("sharded_backbone_error", 0.0, out.stderr[-400:].replace("\n", ";"))


def run() -> None:
    analytic_study()
    sharded_study()
