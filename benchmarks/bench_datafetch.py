"""Fig 11: data-engine transfer latency across tensor sizes + the actual
intermediate tensor sizes of SD3/Flux workflows."""

from benchmarks.common import emit
from repro.core.profiles import GPU_H800, ProfileStore
from repro.diffusion import FAMILIES


def run() -> None:
    profiles = ProfileStore(GPU_H800)
    for size in (2**10, 2**14, 2**17, 2**20, 2**24, 2**27, 2**29):
        t = profiles.transfer_time(size)
        emit(f"fig11_fetch[{size/2**20:.3f}MiB]", t * 1e6,
             f"under_1ms={t < 1e-3}")
    for fam in ("sd3", "flux-dev"):
        f = FAMILIES[fam]
        sizes = {
            "prompt_embeds": f.text_tokens * 4096 * 2.0,
            "latents": f.image_tokens * 16 * 2.0,
            "cn_residuals_per_step": f.controlnet_residual_bytes(),
            "per_request_total": f.controlnet_residual_bytes() * f.denoise_steps,
        }
        for k, v in sizes.items():
            emit(f"fig11_tensor[{fam},{k}]",
                 profiles.transfer_time(v) * 1e6, f"{v/2**20:.1f}MiB")
