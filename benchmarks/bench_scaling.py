"""Fig 3-left: loading time of whole-workflow scaling vs base-DM-only
scaling.  Micro-serving loads only the bottleneck model (L1)."""

from benchmarks.common import emit
from repro.core.profiles import GPU_H800
from repro.diffusion import FAMILIES


def run() -> None:
    hw = GPU_H800
    for name in ("sd3", "sd3.5-large", "flux-schnell", "flux-dev"):
        f = FAMILIES[name]
        full = f.workflow_footprint() / hw.host_load_bw
        dm = f.backbone_bytes() / hw.host_load_bw
        emit(f"fig3_load_workflow[{name}]", full * 1e6,
             f"footprint={f.workflow_footprint()/2**30:.1f}GiB")
        emit(f"fig3_load_dm_only[{name}]", dm * 1e6,
             f"reduction={100*(1-dm/full):.0f}%")
