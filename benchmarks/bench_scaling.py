"""Fig 3-left: loading time of whole-workflow scaling vs base-DM-only
scaling.  Micro-serving loads only the bottleneck model (L1).

Plus the measured counterpart: time for the per-model autoscaler to add
one unit of bottleneck capacity (provision -> warm -> serving, observed
on the event timeline) vs the whole-workflow load a monolithic system
pays for the same scale-up."""

from benchmarks.common import build_lego, canonical_solo, emit
from repro.core.profiles import GPU_H800
from repro.diffusion import FAMILIES, table2_setting


def run() -> None:
    hw = GPU_H800
    for name in ("sd3", "sd3.5-large", "flux-schnell", "flux-dev"):
        f = FAMILIES[name]
        full = f.workflow_footprint() / hw.host_load_bw
        dm = f.backbone_bytes() / hw.host_load_bw
        emit(f"fig3_load_workflow[{name}]", full * 1e6,
             f"footprint={f.workflow_footprint()/2**30:.1f}GiB")
        emit(f"fig3_load_dm_only[{name}]", dm * 1e6,
             f"reduction={100*(1-dm/full):.0f}%")
    reprovision_study()


def reprovision_study(base: int = 2, reserve: int = 2) -> None:
    """Saturate a small fleet with one workflow and watch the autoscaler
    bring a reserve executor into service for the bottleneck model."""
    wfs = table2_setting("s1")
    sys_ = build_lego(wfs, base, autoscaler=True, reserve_executors=reserve)
    name = sorted(wfs)[0]
    solo = canonical_solo(wfs)[name]
    for i in range(24):
        sys_.submit(name, inputs={"prompt": "p", "seed": i},
                    arrival=i * 0.05, slo_seconds=4 * solo)
    sys_.run()
    c = sys_.coordinator
    ups = c.scale_actions("scale_up")
    grow = [(t, n) for t, n in c.fleet_log if n > base]
    if not ups or not grow:
        emit("fig3_reprovision_micro", 0.0, "no_scale_up_observed")
        return
    t0 = ups[0].at
    micro = grow[0][0] - t0           # provision + warm of ONE model
    graph = sys_.registry.instantiate(name)
    whole_bytes = sum(
        {n.op.model_id: n.op.cost().param_bytes for n in graph.nodes
         if not (n.attrs.get("inline") or n.attrs.get("io_only"))}.values()
    )
    whole = whole_bytes / sys_.profiles.hw.host_load_bw
    emit("fig3_reprovision_micro", micro * 1e6,
         f"model={ups[0].model_id};workflow={name}")
    emit("fig3_reprovision_workflow", whole * 1e6,
         f"footprint={whole_bytes/2**30:.1f}GiB;speedup={whole/max(micro,1e-9):.1f}x")
