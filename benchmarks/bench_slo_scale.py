"""Fig 9 (g): SLO attainment vs SLO scale (S6, 16 GPUs, rate 1.0)."""

from benchmarks.common import emit, run_lego_trace, run_mono_trace
from repro.diffusion import table2_setting
from repro.sim import generate_trace


def run() -> None:
    wfs = table2_setting("s6")
    trace = generate_trace(list(wfs), rate=1.0, duration=240, cv=2.0, seed=13)
    first_lego_90 = None
    first_s_90 = None
    for scale in (1.0, 2.0, 4.0, 8.0, 12.0):
        lego = run_lego_trace(wfs, trace, 16, slo_scale=scale).slo_attainment()
        s = run_mono_trace(wfs, trace, 16, "diffusers-s", slo_scale=scale
                           ).slo_attainment()
        if first_lego_90 is None and lego >= 0.9:
            first_lego_90 = scale
        if first_s_90 is None and s >= 0.9:
            first_s_90 = scale
        emit(f"fig9g_slo_scale[{scale}]", scale * 1e6,
             f"lego={lego:.2f};diffusers-s={s:.2f}")
    if first_lego_90 and first_s_90:
        emit("fig9g_stringency_ratio", first_lego_90 * 1e6,
             f"{first_s_90/first_lego_90:.1f}x more stringent SLO satisfied")
