"""Fig 10-right: SLO-aware admission control on/off under overload
(settings S1-S4, high rate scale)."""

from benchmarks.common import emit, run_lego_trace
from repro.diffusion import table2_setting
from repro.sim import generate_trace


def run() -> None:
    for s in ("s1", "s2", "s3", "s4"):
        wfs = table2_setting(s)
        trace = generate_trace(list(wfs), rate=6.0, duration=120, cv=2.0, seed=29)
        on = run_lego_trace(wfs, trace, 8, slo_scale=2.0, admission=True
                            ).slo_attainment()
        off = run_lego_trace(wfs, trace, 8, slo_scale=2.0, admission=False
                             ).slo_attainment()
        emit(f"fig10_admission[{s}]", 0.0,
             f"with_ac={on:.2f};without_ac={off:.2f}")
