"""Table 3: effective LoC to express the three diffusion-specific
optimizations, plus whether each adapts at runtime.

Counted from the actual source: the lines a developer writes/reads for
the mechanism (measured with ``inspect``), not the whole framework.
LegoDiffusion's numbers in the paper: latent parallel 74 (Yes),
ControlNet parallel 79 (Yes), async LoRA 61 (Yes).
"""

from __future__ import annotations

import inspect

from benchmarks.common import emit


def _loc(obj) -> int:
    src = inspect.getsource(obj)
    return sum(1 for l in src.splitlines()
               if l.strip() and not l.strip().startswith("#"))


def run() -> None:
    from repro.core.passes import AsyncLoRAPass, LoRAFetch
    from repro.diffusion import sampler
    from repro.diffusion.serving import DiffusionBackbone

    latent = _loc(sampler.latent_parallel_velocity)
    emit("table3_latent_parallel_loc", latent,
         f"{latent} LoC (adaptive: yes — scheduler picks k per batch); "
         "paper lego=74, katz=92(no), xdit=68(no)")

    # ControlNet parallelism = declaring the input deferred (1 line in the
    # model) + the deferred-fetch consumption contract in the backbone
    cn = _loc(DiffusionBackbone.setup_io)
    emit("table3_controlnet_parallel_loc", cn,
         f"{cn} LoC in the model decl (runtime machinery is generic); "
         "paper lego=79, katz=127(no)")

    lora = _loc(AsyncLoRAPass) + _loc(LoRAFetch)
    emit("table3_async_lora_loc", lora,
         f"{lora} LoC for the compiler pass + fetch op; workflow dev "
         "writes 1 line (add_patch); paper lego=61, katz=182")
