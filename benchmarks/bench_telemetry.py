"""Telemetry overhead study — the observability tax, measured.

Three numbers, emitted to ``BENCH_telemetry.json``:

* **batched-exec overhead**: waves of B=4 simultaneous basic-sd3
  requests on one in-process executor, tracer off vs on, interleaved
  round-robin so host timing noise hits both arms alike.  The gate the
  repo documents is <=5% img/s overhead with tracing ON (the off path is
  guarded to build nothing, so its overhead is unmeasurably small).
* **disabled hot-path cost**: nanoseconds per guarded instrumentation
  call on the ``REPRO_TELEMETRY=0`` path (the ``if tracer.enabled:``
  pattern every runtime site uses, against the shared no-op tracer).
* **proc-plane overhead**: one traced process-isolated run vs untraced.
  Reported honestly, NOT gated: span context rides every exec RPC and
  worker replies carry spans, so the proc tax is real wire bytes — but
  it is paid only when tracing is on.

CLI: ``python -m benchmarks.bench_telemetry [--smoke]`` (CI liveness
check with tiny trial counts — not a measurement).
"""

import argparse
import os
import time

from benchmarks.common import emit
from benchmarks.emit import write_bench_json
from repro.core import (
    LocalBackend,
    ProcBackend,
    ProcConfig,
    Scheduler,
    ServingSystem,
    processes_available,
)
from repro.core.telemetry import MetricsRegistry
from repro.core.tracing import NULL_TRACER, Tracer
from repro.diffusion import FAMILIES, ModelSet, make_basic_workflow

TELEMETRY_JSON = os.path.join(os.path.dirname(__file__), "..",
                              "BENCH_telemetry.json")


class _Arm:
    """One executable-plane arm (tracer off or on), jit-warmed at build."""

    def __init__(self, tracer, n_requests: int = 4, steps: int = 3):
        self.n_requests = n_requests
        self.steps = steps
        self.tracer = tracer
        self.backend = LocalBackend()
        self.sys = ServingSystem(n_executors=1, backend=self.backend,
                                 tracer=tracer, metrics=MetricsRegistry())
        self.sys.coordinator.scheduler = Scheduler(
            self.sys.profiles, max_batch_cap=n_requests,
            use_declared_max_batch=True)
        self.wf = make_basic_workflow("sd3", ModelSet(FAMILIES["sd3"]))
        self.sys.register(self.wf)
        self._trial = 0
        self._wave("warm wave")              # compile every jit variant
        self.waves: list = []

    def _wave(self, prompt: str) -> float:
        import jax

        coord = self.sys.coordinator
        base = coord.now
        self._trial += 1
        t0 = time.perf_counter()
        reqs = [
            self.sys.submit(
                self.wf.name,
                inputs={"seed": 100 * self._trial + i, "prompt": prompt},
                arrival=base, steps=self.steps)
            for i in range(self.n_requests)
        ]
        self.sys.run()
        for r in reqs:
            img = coord.engine.value_of(r.ref_key(r.graph.outputs["image"]))
            jax.block_until_ready(img)
        return time.perf_counter() - t0

    def run_trial(self) -> None:
        self.waves.append(self._wave("measured wave"))

    @property
    def wave_seconds(self) -> float:
        ordered = sorted(self.waves)
        n = len(ordered)
        mid = n // 2
        return ordered[mid] if n % 2 else 0.5 * (ordered[mid - 1] + ordered[mid])


def batched_overhead_study(trials: int = 16) -> dict:
    """Interleaved off/on waves; median img/s per arm."""
    off = _Arm(NULL_TRACER)
    on = _Arm(Tracer())
    for _ in range(trials):
        off.run_trial()
        on.run_trial()
    ips_off = off.n_requests / off.wave_seconds
    ips_on = on.n_requests / on.wave_seconds
    overhead_pct = 100.0 * (1.0 - ips_on / ips_off)
    emit("s8_telemetry_batched_off", off.wave_seconds * 1e6,
         f"{ips_off:.2f} img/s (B={off.n_requests}, {trials} waves)")
    emit("s8_telemetry_batched_on", on.wave_seconds * 1e6,
         f"{ips_on:.2f} img/s; overhead={overhead_pct:+.2f}% (gate <=5%); "
         f"{len(on.tracer.events)} events recorded")
    return {
        "B": off.n_requests,
        "waves": trials,
        "images_per_s_off": ips_off,
        "images_per_s_on": ips_on,
        "overhead_pct": overhead_pct,
        "trace_events": len(on.tracer.events),
    }


def disabled_hot_path_study(n: int = 2_000_000) -> float:
    """ns per guarded call on the disabled path: the exact pattern every
    instrumentation site uses (attribute test, no argument building)."""
    tr = NULL_TRACER
    t0 = time.perf_counter()
    hits = 0
    for i in range(n):
        if tr.enabled:              # pragma: no cover - never taken
            tr.instant("x", float(i), 0, "t")
            hits += 1
    ns = (time.perf_counter() - t0) / n * 1e9
    assert hits == 0
    emit("s8_telemetry_disabled_call", ns / 1e3,
         f"{ns:.1f} ns per guarded call ({n} calls, no-op tracer)")
    return ns


def _proc_run(tracer, steps: int = 5) -> tuple:
    cfg = ProcConfig(hb_interval=0.02, hb_timeout=2.0, spawn_timeout=120.0)
    sys_ = ServingSystem(n_executors=2, backend=ProcBackend(cfg),
                         tracer=tracer, metrics=MetricsRegistry())
    sys_.coordinator.scheduler = Scheduler(
        sys_.profiles, use_declared_max_batch=True, segment_chunk=2)
    wf = make_basic_workflow("sd3")
    sys_.register(wf)
    with sys_:
        t0 = time.perf_counter()
        req = sys_.submit(wf.name, inputs={"seed": 0, "prompt": "a fox"},
                          arrival=0.0, steps=steps)
        sys_.run()
        wall = time.perf_counter() - t0
    assert req.status == "done"
    return wall, len(tracer.events)


def proc_overhead_study(steps: int = 5) -> dict:
    """Traced vs untraced proc-plane run.  Documented, not gated: most of
    the wall is worker spawn + real RPC, so run-to-run spawn noise easily
    exceeds the span tax — the honest number here is the event count and
    the single-run delta, not a tight bound."""
    if not processes_available():
        emit("s8_telemetry_proc", 0.0, "SKIPPED: cannot spawn processes")
        return {"skipped": True}
    wall_off, _ = _proc_run(NULL_TRACER, steps)
    tr = Tracer()
    wall_on, n_events = _proc_run(tr, steps)
    overhead_pct = 100.0 * (wall_on - wall_off) / wall_off
    emit("s8_telemetry_proc", wall_on * 1e6,
         f"traced={wall_on:.2f}s vs untraced={wall_off:.2f}s "
         f"({overhead_pct:+.1f}%, spawn-noise dominated); "
         f"{n_events} events incl. worker spans")
    return {
        "wall_off_s": wall_off,
        "wall_on_s": wall_on,
        "overhead_pct": overhead_pct,
        "trace_events": n_events,
        "note": "single-run delta; worker spawn noise dominates the span "
                "tax, see batched_exec for the gated overhead number",
    }


def run(smoke: bool = False) -> None:
    out = {
        "smoke": smoke,
        "batched_exec": batched_overhead_study(trials=4 if smoke else 16),
        "disabled_hot_path_ns": disabled_hot_path_study(
            n=200_000 if smoke else 2_000_000),
        "proc": proc_overhead_study(steps=3 if smoke else 5),
    }
    write_bench_json("telemetry", out, path=TELEMETRY_JSON, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trial counts — CI liveness check, not a "
                         "measurement")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
