"""Shared benchmark harness utilities.

Every benchmark prints ``name,us_per_call,derived`` CSV rows via
:func:`emit`; ``us_per_call`` is the benchmark's primary latency-like
quantity in microseconds (or the sim wall quantity it measures), and
``derived`` carries the paper-comparable ratio/percentage.
"""

from __future__ import annotations

import sys
import time
from typing import Any, Dict, Iterable, List, Optional

from repro.core import (
    AdmissionController,
    GraphCompiler,
    ProfileStore,
    Scheduler,
    ServingSystem,
)
from repro.core.profiles import GPU_H800
from repro.diffusion import table2_setting
from repro.sim import MonolithicSystem, WorkflowSpec, generate_trace, mean_fleet_size


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def serving_horizon(coordinator) -> float:
    """End of the serving period for time-weighted fleet metrics: the last
    request completion.  Using ``coordinator.now`` would include the
    autoscaler's post-trace linger ticks, when the fleet idles at its
    minimum, and flatter the mean fleet size."""
    return max((r.completion for r in coordinator.finished
                if r.completion is not None), default=coordinator.now)


def build_lego(
    workflows: Dict[str, Any],
    n_executors: int,
    admission: bool = True,
    scheduler: Optional[Scheduler] = None,
    scheduler_kwargs: Optional[Dict[str, Any]] = None,
    autoscaler: Any = None,
    reserve_executors: int = 0,
    faults: Any = None,
    retry_policy: Any = None,
    replicate_segments: bool = False,
) -> ServingSystem:
    sys_ = ServingSystem(
        n_executors=n_executors, admission_enabled=admission, scheduler=scheduler,
        autoscaler=autoscaler, reserve_executors=reserve_executors,
        faults=faults, retry_policy=retry_policy,
        replicate_segments=replicate_segments,
    )
    if scheduler_kwargs:
        sys_.coordinator.scheduler = Scheduler(sys_.profiles, **scheduler_kwargs)
    for t in workflows.values():
        sys_.register(t)
    return sys_


def canonical_solo(workflows: Dict[str, Any]) -> Dict[str, float]:
    """One solo latency per workflow, shared by ALL systems (paper §7.1:
    the deadline is a property of the workflow, not of the serving
    system): the monolithic single-request serial latency."""
    profiles = ProfileStore(GPU_H800)
    reg = ServingSystem(n_executors=1)
    for t in workflows.values():
        reg.register(t)
    return {
        n: WorkflowSpec.from_graph(reg.registry.instantiate(n), profiles)
        .serial_seconds_b1
        for n in workflows
    }


def run_lego_trace(
    workflows: Dict[str, Any],
    trace,
    n_executors: int,
    slo_scale: Optional[float] = 2.0,
    admission: bool = True,
    scheduler: Optional[Scheduler] = None,
    scheduler_kwargs: Optional[Dict[str, Any]] = None,
    solo: Optional[Dict[str, float]] = None,
    autoscaler: Any = None,
    reserve_executors: int = 0,
    faults: Any = None,
    retry_policy: Any = None,
    replicate_segments: bool = False,
) -> ServingSystem:
    sys_ = build_lego(workflows, n_executors, admission, scheduler,
                      scheduler_kwargs, autoscaler=autoscaler,
                      reserve_executors=reserve_executors, faults=faults,
                      retry_policy=retry_policy,
                      replicate_segments=replicate_segments)
    solo = solo or canonical_solo(workflows)
    for tr in trace:
        sys_.submit(
            tr.workflow, inputs=tr.inputs, arrival=tr.arrival,
            slo_seconds=None if slo_scale is None else slo_scale * solo[tr.workflow],
        )
    sys_.run()
    return sys_


def build_mono(
    workflows: Dict[str, Any], n_gpus: int, mode: str, admission: bool = True
) -> MonolithicSystem:
    profiles = ProfileStore(GPU_H800)
    reg = ServingSystem(n_executors=1)
    for t in workflows.values():
        reg.register(t)
    specs = {
        n: WorkflowSpec.from_graph(reg.registry.instantiate(n), profiles)
        for n in workflows
    }
    return MonolithicSystem(n_gpus, profiles, specs, mode=mode, admission=admission)


def run_mono_trace(
    workflows: Dict[str, Any],
    trace,
    n_gpus: int,
    mode: str,
    slo_scale: Optional[float] = 2.0,
    admission: bool = True,
) -> MonolithicSystem:
    m = build_mono(workflows, n_gpus, mode, admission)
    solo = {n: m.specs[n].serial_seconds_b1 for n in workflows}
    for tr in trace:
        m.submit(tr.arrival, tr.workflow,
                 None if slo_scale is None else slo_scale * solo[tr.workflow])
    m.run()
    return m


def attainment_at(workflows, rate: float, n: int, cv: float, slo: float,
                  duration: float = 180.0, seed: int = 7,
                  with_autoscaled: bool = False) -> Dict[str, float]:
    """Attainment of lego + the three baselines on one trace.  With
    ``with_autoscaled``, also a per-model-autoscaled lego fleet holding
    half the devices in cold reserve (same ``n`` total devices): key
    ``lego-auto``, plus its time-weighted mean fleet size
    ``lego-auto-fleet``."""
    trace = generate_trace(list(workflows), rate=rate, duration=duration,
                           cv=cv, seed=seed)
    out = {"n_requests": float(len(trace))}
    out["lego"] = run_lego_trace(workflows, trace, n, slo).slo_attainment()
    if with_autoscaled:
        base = max(1, n // 2)
        sys_ = run_lego_trace(workflows, trace, base, slo, autoscaler=True,
                              reserve_executors=n - base)
        out["lego-auto"] = sys_.slo_attainment()
        out["lego-auto-fleet"] = mean_fleet_size(
            sys_.coordinator.fleet_log, serving_horizon(sys_.coordinator), base)
    for mode in ("diffusers", "diffusers-c", "diffusers-s"):
        out[mode] = run_mono_trace(workflows, trace, n, mode, slo).slo_attainment()
    return out


def max_rate_at_target(workflows, n: int, cv: float, slo: float,
                       target: float = 0.9, rates: Iterable[float] = None,
                       system: str = "lego") -> float:
    """Highest swept rate sustaining `target` attainment."""
    rates = list(rates or (0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0))
    best = 0.0
    for r in rates:
        trace = generate_trace(list(workflows), rate=r, duration=180, cv=cv, seed=11)
        if system == "lego":
            a = run_lego_trace(workflows, trace, n, slo).slo_attainment()
        else:
            a = run_mono_trace(workflows, trace, n, system, slo).slo_attainment()
        if a >= target:
            best = r
    return best
