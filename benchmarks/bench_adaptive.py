"""Fig 4-right: adaptive parallelism vs fixed parallelism (3 SD3
workflows, 4 GPUs)."""

from benchmarks.common import emit, run_lego_trace
from repro.diffusion import table2_setting
from repro.sim import generate_trace


def run() -> None:
    wfs = table2_setting("s1")
    trace = generate_trace(list(wfs), rate=1.2, duration=240, cv=1.0, seed=9)
    lats = {}
    for tag, kw in (
        ("p1", {"fixed_parallelism": 1}),
        ("p2", {"fixed_parallelism": 2}),
        ("adaptive", None),
    ):
        sys_ = run_lego_trace(wfs, trace, 4, slo_scale=None, admission=False,
                              scheduler_kwargs=kw)
        lats[tag] = sys_.mean_latency()
        emit(f"fig4_adaptive[{tag}]", lats[tag] * 1e6, "")
    emit("fig4_adaptive_speedup_vs_p1", lats["adaptive"] * 1e6,
         f"{lats['p1']/lats['adaptive']:.2f}x")
    emit("fig4_adaptive_speedup_vs_p2", lats["adaptive"] * 1e6,
         f"{lats['p2']/lats['adaptive']:.2f}x")
