"""Fig 3-right: per-model latency-throughput tradeoffs in an SD3 workflow
(heterogeneous arithmetic intensities => no single static config fits)."""

from benchmarks.common import emit
from repro.core.profiles import GPU_H800, ProfileStore
from repro.diffusion import ModelSet, FAMILIES


def run() -> None:
    profiles = ProfileStore(GPU_H800)
    ms = ModelSet(FAMILIES["sd3"])
    for model in (ms.text_enc, ms.backbone, ms.cn1, ms.vae_dec):
        p = profiles.profile_model(model)
        for b in (1, 2, 4, 8):
            t = p.infer_time(b)
            emit(f"fig3_latency[{model.model_id},b={b}]", t * 1e6,
                 f"throughput={b/t:.2f}/s")
