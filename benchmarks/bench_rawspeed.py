"""Raw-speed pass: quant x donate x overlap grid (modeled + executable).

Serves a wave of B basic sd3 requests at S steps per request on one
executor for every combination of the three raw-speed levers:

* ``quant``   — int8 w8a8 / fp8 weight-only backbone forwards
  (``REPRO_QUANT``);
* ``donate``  — donated latent scan buffers (``REPRO_DONATE``);
* ``overlap`` — denoise/decode pipeline overlap (``REPRO_OVERLAP``).

Two planes, two jobs:

* **modeled grid** — every arm runs on the discrete-event timeline
  priced by the H800 roofline (quant-aware: int8 doubles the MXU issue
  rate and halves the weight stream; fp8 halves residency only; overlap
  prices hidden decodes at exposed cost).  This is where the raw-speed
  win is a *hardware* statement, and it is what the **1.3x images/s
  gate** (all-on int8+donate+overlap vs all-off) is asserted on —
  off-accelerator the int8 jnp fallback merely emulates the arithmetic,
  so real CPU walls cannot witness an MXU issue-rate win.
* **executable validation** — representative arms run real forwards:
  parity vs the fp32 oracle (quant correctness end to end), the
  backend's ACTUAL resident model bytes (the ~2x f32→int8 shrink), real
  overlap dispatches, and the donation lever really engaged.

Methodology notes (why each arm looks the way it does):

* ``segment_chunk=S`` pins the scan chunk — the load-adaptive policy
  would otherwise pick different chunk shapes per arm and the resulting
  fresh jit compiles would land in the measured walls;
* every executable arm runs once untimed first (same shapes), so XLA
  compile time never pollutes the measured wave;
* ``max_batch_cap=1`` staggers the wave into successive single-request
  batches — the batch-N-decode-over-batch-N+1-denoise pattern overlap
  needs (one stacked wave would leave nothing to pipeline).

Results land in ``BENCH_rawspeed.json`` through the shared
:mod:`benchmarks.emit` envelope.
"""

from __future__ import annotations

import argparse
import itertools
import os
from typing import Any, Dict, List, Optional

import numpy as np

from benchmarks.common import emit
from benchmarks.emit import write_bench_json
from repro.core import LocalBackend, Scheduler, ServingSystem
from repro.core.executor import _tree_bytes
from repro.diffusion import make_basic_workflow
from repro.diffusion.sampler import set_donate_buffers
from repro.nn.layers import set_quant_mode

OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_rawspeed.json")

QUANT_MODES = ("off", "int8", "fp8")
GATE_SPEEDUP = 1.3

# executable-plane validation arms: the fp32 oracle, each lever alone,
# and the gated all-on configuration
REAL_ARMS = (("off", False, False), ("int8", False, False),
             ("off", True, True), ("int8", True, True))


def _serve_wave(n_requests: int, steps: int, overlap: bool,
                backend: Optional[LocalBackend]) -> Dict[str, Any]:
    sys_ = ServingSystem(n_executors=1, backend=backend, overlap=overlap)
    sys_.coordinator.scheduler = Scheduler(
        sys_.profiles, use_declared_max_batch=True, max_batch_cap=1,
        segment_chunk=steps)
    wf = make_basic_workflow("sd3")
    sys_.register(wf)
    reqs = [sys_.submit(wf.name, inputs={"seed": i, "prompt": f"p{i}"},
                        arrival=0.0, steps=steps) for i in range(n_requests)]
    sys_.run()
    assert all(r.status == "done" for r in reqs), [r.status for r in reqs]
    co = sys_.coordinator
    out: Dict[str, Any] = {
        "makespan_s": co.now,
        "images_per_s": n_requests / co.now,
        "n_overlap_dispatches": co.n_overlap_dispatches,
        "overlap_hidden_s": co.overlap_hidden_seconds,
    }
    if backend is not None:
        out["resident_bytes"] = (
            sum(_tree_bytes(c) for c in backend._components.values())
            + backend.folded_resident_bytes
            + backend.adapter_pool.resident_bytes)
        out["images"] = [np.asarray(sys_.coordinator.engine.value_of(
            r.ref_key(r.graph.outputs["image"]))) for r in reqs]
    return out


def _arm(quant: str, donate: bool, overlap: bool, n_requests: int,
         steps: int, real: bool) -> Dict[str, Any]:
    prev_q = set_quant_mode(quant)
    prev_d = set_donate_buffers(donate)
    try:
        if real:
            _serve_wave(n_requests, steps, overlap,
                        LocalBackend())            # warm jit caches
            out = _serve_wave(n_requests, steps, overlap, LocalBackend())
        else:
            out = _serve_wave(n_requests, steps, overlap, None)
    finally:
        set_donate_buffers(prev_d)
        set_quant_mode(prev_q)
    return out


def run(smoke: bool = False) -> Dict[str, Any]:
    n_requests = 3 if smoke else 8
    steps = 3 if smoke else 8
    grid = ([("off", False, False), ("int8", True, True)] if smoke else
            list(itertools.product(QUANT_MODES, (False, True),
                                   (False, True))))

    # ----------------------------------------------------- modeled grid
    rows: List[Dict[str, Any]] = []
    for quant, donate, overlap in grid:
        r = _arm(quant, donate, overlap, n_requests, steps, real=False)
        row = {"quant": quant, "donate": donate, "overlap": overlap, **r}
        rows.append(row)
        emit(f"rawspeed_{quant}_d{int(donate)}_o{int(overlap)}",
             1e6 * row["makespan_s"] / n_requests,
             f"{row['images_per_s']:.2f} img/s (modeled) "
             f"overlap_n={row['n_overlap_dispatches']}")

    def _find(rs, q, d, o):
        return next(r for r in rs
                    if (r["quant"], r["donate"], r["overlap"]) == (q, d, o))

    base = _find(rows, "off", False, False)
    full = _find(rows, "int8", True, True)
    speedup = full["images_per_s"] / base["images_per_s"]
    gate_ok = speedup >= GATE_SPEEDUP

    # ------------------------------------------- executable validation
    real_arms = ([("off", False, False), ("int8", True, True)] if smoke
                 else list(REAL_ARMS))
    real_rows: List[Dict[str, Any]] = []
    ref_images = None
    for quant, donate, overlap in real_arms:
        r = _arm(quant, donate, overlap, n_requests, steps, real=True)
        images = r.pop("images")
        if ref_images is None:
            ref_images = images            # first arm is the fp32 oracle
        if quant == "off":
            parity = max(float(np.abs(a - b).max())
                         for a, b in zip(images, ref_images))
        else:
            parity = max(float(np.linalg.norm(a - b) / np.linalg.norm(b))
                         for a, b in zip(images, ref_images))
        row = {"quant": quant, "donate": donate, "overlap": overlap,
               "parity_vs_fp32": parity, **r}
        real_rows.append(row)
        emit(f"rawspeed_real_{quant}_d{int(donate)}_o{int(overlap)}",
             1e6 * row["makespan_s"] / n_requests,
             f"{row['images_per_s']:.2f} img/s (real walls) "
             f"resident={row['resident_bytes']/2**20:.2f}MiB "
             f"overlap_n={row['n_overlap_dispatches']} "
             f"parity={parity:.2e}")

    real_base = _find(real_rows, "off", False, False)
    real_full = _find(real_rows, "int8", True, True)
    shrink = (real_base["resident_bytes"]
              / max(1.0, real_full["resident_bytes"]))
    result = {
        "smoke": smoke,
        "n_requests": n_requests,
        "steps_per_request": steps,
        "modeled_grid": rows,
        "real_validation": real_rows,
        "allon_speedup_modeled": speedup,
        "resident_shrink_real": shrink,
        "gate_speedup": GATE_SPEEDUP,
        "pass_1p3x": gate_ok,
    }
    write_bench_json("rawspeed", result, gates={"pass_1p3x": gate_ok},
                     path=OUT_JSON)
    emit("rawspeed_allon_speedup", speedup * 100,
         f"{speedup:.2f}x vs all-off on the modeled timeline (gate "
         f"{GATE_SPEEDUP}x: {'pass' if gate_ok else 'FAIL'}); "
         f"real resident shrink {shrink:.2f}x")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="two arms, tiny wave (CI liveness, not a "
                         "measurement)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    result = run(smoke=args.smoke)
    print(f"allon_speedup={result['allon_speedup_modeled']:.2f}x "
          f"pass_1p3x={result['pass_1p3x']}")


if __name__ == "__main__":
    main()
