"""§7.5: micro-serving system overheads.

* end-to-end overhead of node decomposition vs a monolithic run of the
  same models (executable plane, tiny models, measured);
* coordinator (control-plane) share of execution at 256 executors / 500
  inflight requests (simulation);
* data-transmission share per request (sim accounting);
* batched vs sequential executable plane: B simultaneous requests stacked
  into one forward per (model, ScheduledBatch) vs per-request dispatch —
  images/s at B=1/2/4/8 and per-node dispatch overhead, emitted to
  ``BENCH_batched_exec.json``;
* segment-size study: fixed scan chunks S=1/2/4/full vs the adaptive
  chunk policy, at low load (solo requests) and high load (staggered
  waves), emitted to ``BENCH_segments.json``.

CLI: ``python -m benchmarks.bench_overhead [--study segments] [--smoke]``
runs one study standalone (the CI smoke job uses this)."""

import argparse
import os
import time

from benchmarks.common import emit, run_lego_trace
from benchmarks.emit import write_bench_json
from repro.core import LocalBackend, Scheduler, ServingSystem
from repro.diffusion import FAMILIES, ModelSet, make_basic_workflow, table2_setting
from repro.sim import generate_trace

BATCHED_JSON = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_batched_exec.json")
SEGMENTS_JSON = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_segments.json")


class _PlaneArm:
    """One executable-plane measurement arm: waves of ``n_requests``
    simultaneous basic-sd3 requests on one executor, cross-request batch
    capped at ``max_batch_cap``.

    A warm-up wave with the identical arrival pattern runs at build time
    so every (model, batch-size) jit variant is compiled before
    measurement.  Dispatch overhead is control-plane handler time MINUS
    measured device seconds — the coordinator executes batches inside its
    handlers on this plane."""

    def __init__(self, n_requests: int, max_batch_cap: int, steps: int = 3):
        self.n_requests = n_requests
        self.steps = steps
        self.backend = LocalBackend()
        self.sys = ServingSystem(n_executors=1, backend=self.backend)
        self.sys.coordinator.scheduler = Scheduler(
            self.sys.profiles, max_batch_cap=max_batch_cap,
            use_declared_max_batch=True)
        self.wf = make_basic_workflow("sd3", ModelSet(FAMILIES["sd3"]))
        self.sys.register(self.wf)
        self._trial = 0
        self._wave("warm wave")              # compile every jit variant
        self.waves: list = []                # wall seconds per measured wave
        self.forwards = self.dispatches = 0
        self.overhead = 0.0

    def _wave(self, prompt: str) -> float:
        """One wave; returns WALL seconds from first submit to every output
        image materialized (jax dispatch is async — the event timeline's
        measured durations undercount compute, wall + block does not)."""
        import jax

        coord = self.sys.coordinator
        base = coord.now
        self._trial += 1
        t0 = time.perf_counter()
        reqs = [
            self.sys.submit(
                self.wf.name,
                inputs={"seed": 100 * self._trial + i, "prompt": prompt},
                arrival=base, steps=self.steps)
            for i in range(self.n_requests)
        ]
        self.sys.run()
        for r in reqs:
            img = coord.engine.value_of(r.ref_key(r.graph.outputs["image"]))
            jax.block_until_ready(img)
        return time.perf_counter() - t0

    def run_trial(self) -> None:
        coord = self.sys.coordinator
        n_fwd = len(self.backend.forward_log)
        n_disp = len(coord.dispatch_log)
        cp0 = coord.control_plane_time
        ex0 = self.backend.exec_seconds
        wall = self._wave("measured wave")
        self.waves.append(wall)
        if len(self.waves) == 1:
            # dispatch/forward structure is deterministic across waves
            self.forwards = len(self.backend.forward_log) - n_fwd
            self.dispatches = len(coord.dispatch_log) - n_disp
        cp = coord.control_plane_time - cp0
        ex = self.backend.exec_seconds - ex0
        self.overhead += (max(0.0, cp - ex) / max(1, self.dispatches)
                          - self.overhead) / len(self.waves)   # running mean

    @property
    def wave_seconds(self) -> float:
        """Median wave wall time — robust to slow AND lucky-fast outliers."""
        ordered = sorted(self.waves)
        n = len(ordered)
        mid = n // 2
        return ordered[mid] if n % 2 else 0.5 * (ordered[mid - 1] + ordered[mid])


def batched_exec_study(trials: int = 24, steps: int = 2) -> None:
    """Batched-vs-sequential executable plane at B = 1/2/4/8.

    Each arm serves waves of B simultaneous requests: the batched arm
    stacks them (cap=B, one forward per (model, ScheduledBatch)), the
    sequential arm dispatches per request (cap=1) over the same workload.
    All arms are built (and jit-warmed) up front and trials interleave
    round-robin across them, so host timing-noise bursts hit every arm
    alike; each arm reports its MEDIAN wave time over ``trials`` (robust
    to slow and lucky-fast outliers both).  ``steps=2`` keeps the
    per-image compute share low so the per-node overheads the batching
    engine amortizes stay visible above host noise.

    The study runs on the reference attention path: on CPU the Pallas
    kernel executes in interpret mode — a parity/debugging vehicle whose
    per-call emulation cost would swamp the cross-request-batching signal
    being measured here (compiled Mosaic on TPU is the kernel's
    performance path; ``tests/test_batched_exec.py`` covers its parity)."""
    from repro.nn.layers import set_flash_attention

    sizes = (1, 2, 4, 8)
    prev_flash = set_flash_attention(False)
    try:
        batched = {b: _PlaneArm(b, max_batch_cap=b, steps=steps)
                   for b in sizes}
        sequential = {b: _PlaneArm(b, max_batch_cap=1, steps=steps)
                      for b in sizes}
        for _ in range(trials):
            for b in sizes:
                batched[b].run_trial()
                sequential[b].run_trial()
    finally:
        set_flash_attention(prev_flash)
    rows = []
    for b in sizes:
        arm, seq = batched[b], sequential[b]
        row = {
            "B": b,
            "images_per_s": b / arm.wave_seconds,
            "sequential_images_per_s": b / seq.wave_seconds,
            "speedup_vs_sequential": seq.wave_seconds / arm.wave_seconds,
            "forwards": arm.forwards,
            "sequential_forwards": seq.forwards,
            "dispatches": arm.dispatches,
            "dispatch_overhead_us": 1e6 * arm.overhead,
        }
        rows.append(row)
        emit(f"s75_batched_exec_b{b}", 1e6 * arm.wave_seconds / b,
             f"{row['images_per_s']:.2f} img/s batched vs "
             f"{row['sequential_images_per_s']:.2f} sequential "
             f"({row['speedup_vs_sequential']:.2f}x, {arm.forwards} vs "
             f"{seq.forwards} forwards, "
             f"{row['dispatch_overhead_us']:.0f}us/dispatch overhead)")
    mono = all(rows[i + 1]["images_per_s"] >= rows[i]["images_per_s"]
               for i in range(len(rows) - 1))
    write_bench_json("batched_exec", rows, path=BATCHED_JSON,
                     gates={"throughput_monotone": mono})
    emit("s75_batched_exec_monotone", float(mono),
         f"throughput monotone B=1..8: {mono}; wrote {BATCHED_JSON}")


class _SegmentArm:
    """One segment-granularity arm: a 1-executor executable plane whose
    scheduler runs fixed chunks (``chunk=S``) or the adaptive policy
    (``chunk=None``).  Serves two workloads per trial:

    * **low load** — one solo request per wave (chunk size is pure
      per-node overhead: bigger chunks amortize dispatch);
    * **high load** — a wave of ``high_n`` requests with staggered
      timeline arrivals, so later requests land while earlier ones are
      mid-denoise (small chunks let them merge into step-level batches).

    A warm-up of both patterns runs at build time so every (S, B) scan
    variant is compiled before measurement."""

    def __init__(self, chunk, steps: int, high_n: int = 6):
        self.chunk = chunk
        self.steps = steps
        self.high_n = high_n
        self.backend = LocalBackend()
        self.sys = ServingSystem(n_executors=1, backend=self.backend)
        self.sys.coordinator.scheduler = Scheduler(
            self.sys.profiles, use_declared_max_batch=True,
            segment_chunk=chunk)
        self.wf = make_basic_workflow("sd3", ModelSet(FAMILIES["sd3"]))
        self.sys.register(self.wf)
        self._trial = 0
        self.low_waves: list = []
        self.high_waves: list = []
        self._wave(1)                       # warm: solo pattern
        self._wave(self.high_n)             # warm: staggered pattern
        self.low_dispatches = 0

    def _wave(self, n_requests: int) -> float:
        """One wave; returns wall seconds from first submit to all output
        images materialized.  Requests stagger 1 ms apart on the event
        timeline — at n=1 this is a solo request; at n>1 later arrivals
        find the executor busy with an earlier request's segment."""
        import jax

        coord = self.sys.coordinator
        base = coord.now
        self._trial += 1
        t0 = time.perf_counter()
        reqs = [
            self.sys.submit(
                self.wf.name,
                inputs={"seed": 1000 * self._trial + i, "prompt": "seg probe"},
                arrival=base + 0.001 * i, steps=self.steps)
            for i in range(n_requests)
        ]
        self.sys.run()
        for r in reqs:
            img = coord.engine.value_of(r.ref_key(r.graph.outputs["image"]))
            jax.block_until_ready(img)
        return time.perf_counter() - t0

    def run_trial(self) -> None:
        n_disp = len(self.sys.coordinator.dispatch_log)
        self.low_waves.append(self._wave(1))
        if not self.low_dispatches:
            self.low_dispatches = len(self.sys.coordinator.dispatch_log) - n_disp
        self.high_waves.append(self._wave(self.high_n))

    @staticmethod
    def _median(xs: list) -> float:
        xs = sorted(xs)
        mid = len(xs) // 2
        return xs[mid] if len(xs) % 2 else 0.5 * (xs[mid - 1] + xs[mid])

    @property
    def low_img_s(self) -> float:
        return 1.0 / self._median(self.low_waves)

    @property
    def high_img_s(self) -> float:
        return self.high_n / self._median(self.high_waves)


def segments_study(trials: int = 12, steps: int = 8, high_n: int = 6) -> None:
    """Segment-size study (``BENCH_segments.json``): throughput vs fixed
    chunk size S at batch=1 must grow monotonically (target >=1.3x at
    S=full over S=1), and the adaptive policy must recover >=95% of the
    best fixed chunk at BOTH load points.  Arms are built (and jit-warmed)
    up front and trials interleave round-robin, so timing-noise bursts
    hit every arm alike; medians are reported.  Flash attention is off
    for the same reason as the batched study: interpret-mode Pallas
    emulation would swamp the dispatch-overhead signal on CPU."""
    from repro.nn.layers import set_flash_attention

    sizes = [s for s in (1, 2, 4) if s < steps] + [steps]
    prev_flash = set_flash_attention(False)
    try:
        arms = {f"fixed-{s}": _SegmentArm(s, steps, high_n) for s in sizes}
        arms["adaptive"] = _SegmentArm(None, steps, high_n)
        for _ in range(trials):
            for arm in arms.values():
                arm.run_trial()
    finally:
        set_flash_attention(prev_flash)
    rows = []
    for name, arm in arms.items():
        rows.append({
            "arm": name,
            "chunk": arm.chunk,
            "steps": steps,
            "low_load_images_per_s": arm.low_img_s,
            "high_load_images_per_s": arm.high_img_s,
            "low_load_dispatches_per_request": arm.low_dispatches,
        })
        emit(f"s75_segments_{name}", 1e6 / arm.low_img_s,
             f"{arm.low_img_s:.2f} img/s solo, {arm.high_img_s:.2f} img/s "
             f"at {high_n}-deep load ({arm.low_dispatches} dispatches/req)")
    fixed = [r for r in rows if r["arm"].startswith("fixed-")]
    adaptive = rows[-1]
    mono = all(fixed[i + 1]["low_load_images_per_s"]
               >= fixed[i]["low_load_images_per_s"]
               for i in range(len(fixed) - 1))
    gain = fixed[-1]["low_load_images_per_s"] / fixed[0]["low_load_images_per_s"]
    rec_low = adaptive["low_load_images_per_s"] / max(
        r["low_load_images_per_s"] for r in fixed)
    rec_high = adaptive["high_load_images_per_s"] / max(
        r["high_load_images_per_s"] for r in fixed)
    summary = {
        "monotone_low_load": mono,
        "full_vs_1_speedup": gain,
        "adaptive_recovery_low": rec_low,
        "adaptive_recovery_high": rec_high,
    }
    write_bench_json("segments", {"rows": rows, "summary": summary},
                     path=SEGMENTS_JSON,
                     gates={"monotone_low_load": mono})
    emit("s75_segments_summary", gain * 100,
         f"monotone={mono}; S=full vs S=1: {gain:.2f}x; adaptive recovers "
         f"{100*rec_low:.0f}% (low) / {100*rec_high:.0f}% (high) of best "
         f"fixed; wrote {SEGMENTS_JSON}")


def run() -> None:
    # executable plane: micro-serving vs direct sequential execution.
    # One warm-up request first so jit compilation is excluded from BOTH
    # sides (the paper's 150 ms bound is steady-state overhead).
    backend = LocalBackend()
    ms = ModelSet(FAMILIES["sd3"])
    wf = make_basic_workflow("sd3", ms)
    sys_ = ServingSystem(n_executors=2, backend=backend)
    sys_.register(wf)
    sys_.submit(wf.name, inputs={"seed": 9, "prompt": "warmup"}, steps=4)
    sys_.run()
    r = sys_.submit(wf.name, inputs={"seed": 0, "prompt": "overhead probe"},
                    steps=4)
    t0 = time.perf_counter()
    sys_.run()
    wall = time.perf_counter() - t0
    # direct: run the same (already warm) models inline
    out, d1 = backend.execute(ms.text_enc, prompt="overhead probe")
    lat = ms.latents.execute({}, seed=0)["latents"]
    total = d1
    for i in range(4):
        o, dt = backend.execute(
            ms.backbone, latents=lat, prompt_embeds=out["prompt_embeds"],
            t=0.9, controlnet_residuals=None, guidance=4.5)
        total += dt
        lat = lat + 0.1 * o["velocity"]
    _, dvae = backend.execute(ms.vae_dec, latents=lat)
    total += dvae
    overhead = max(0.0, wall - total)
    emit("s75_exec_overhead", overhead * 1e6,
         f"micro={wall:.2f}s vs direct={total:.2f}s (paper: <=150ms)")

    # control-plane scalability: 256 executors, ~500 inflight
    wfs = table2_setting("s6")
    trace = generate_trace(list(wfs), rate=24.0, duration=30, cv=2.0, seed=31)
    sys2 = run_lego_trace(wfs, trace, 256, slo_scale=None, admission=False)
    busy = sys2.coordinator.total_busy_time()
    cp = sys2.coordinator.control_plane_time
    emit("s75_control_plane_share", cp * 1e6,
         f"{100*cp/max(busy,1e-9):.1f}% of executor busy time "
         f"({len(trace)} requests, 256 executors)")
    eng = sys2.coordinator.engine
    emit("s75_data_plane", eng.bytes_transferred / 2**20,
         f"transfers={eng.num_transfers};local_hits={eng.num_local_hits}")

    # §8: multi-coordinator sharding — same 256-GPU load split across
    # model-sharing clusters; the (max) per-coordinator control-plane time
    # is the scalability figure
    from repro.core import CoordinatorGroup
    group = CoordinatorGroup(wfs, n_executors=256, admission_enabled=False)
    for t in trace:
        group.submit(t.workflow, inputs=t.inputs, arrival=t.arrival)
    group.run()
    cp_g = group.control_plane_time()
    busy_g = group.total_busy_time()
    emit("s75_sharded_control_plane", cp_g * 1e6,
         f"{group.n_coordinators} coordinators; "
         f"{100*cp_g/max(busy_g,1e-9):.1f}% of busy time "
         f"(vs {100*cp/max(busy,1e-9):.1f}% single-coordinator)")

    # batched vs sequential executable plane (BENCH_batched_exec.json)
    batched_exec_study()

    # segment-size study (BENCH_segments.json)
    segments_study()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--study", choices=("all", "segments", "batched"),
                    default="all")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trial counts — CI liveness check, not a "
                         "measurement")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.study == "segments":
        if args.smoke:
            segments_study(trials=2, steps=4, high_n=3)
        else:
            segments_study()
    elif args.study == "batched":
        batched_exec_study(trials=4 if args.smoke else 24)
    else:
        run()


if __name__ == "__main__":
    main()
