"""§7.5: micro-serving system overheads.

* end-to-end overhead of node decomposition vs a monolithic run of the
  same models (executable plane, tiny models, measured);
* coordinator (control-plane) share of execution at 256 executors / 500
  inflight requests (simulation);
* data-transmission share per request (sim accounting)."""

import time

from benchmarks.common import emit, run_lego_trace
from repro.core import LocalBackend, ServingSystem
from repro.diffusion import FAMILIES, ModelSet, make_basic_workflow, table2_setting
from repro.sim import generate_trace


def run() -> None:
    # executable plane: micro-serving vs direct sequential execution.
    # One warm-up request first so jit compilation is excluded from BOTH
    # sides (the paper's 150 ms bound is steady-state overhead).
    backend = LocalBackend()
    ms = ModelSet(FAMILIES["sd3"])
    wf = make_basic_workflow("sd3", ms)
    sys_ = ServingSystem(n_executors=2, backend=backend)
    sys_.register(wf)
    sys_.submit(wf.name, inputs={"seed": 9, "prompt": "warmup"}, steps=4)
    sys_.run()
    r = sys_.submit(wf.name, inputs={"seed": 0, "prompt": "overhead probe"},
                    steps=4)
    t0 = time.perf_counter()
    sys_.run()
    wall = time.perf_counter() - t0
    # direct: run the same (already warm) models inline
    out, d1 = backend.execute(ms.text_enc, prompt="overhead probe")
    lat = ms.latents.execute({}, seed=0)["latents"]
    total = d1
    for i in range(4):
        o, dt = backend.execute(
            ms.backbone, latents=lat, prompt_embeds=out["prompt_embeds"],
            t=0.9, controlnet_residuals=None, guidance=4.5)
        total += dt
        lat = lat + 0.1 * o["velocity"]
    _, dvae = backend.execute(ms.vae_dec, latents=lat)
    total += dvae
    overhead = max(0.0, wall - total)
    emit("s75_exec_overhead", overhead * 1e6,
         f"micro={wall:.2f}s vs direct={total:.2f}s (paper: <=150ms)")

    # control-plane scalability: 256 executors, ~500 inflight
    wfs = table2_setting("s6")
    trace = generate_trace(list(wfs), rate=24.0, duration=30, cv=2.0, seed=31)
    sys2 = run_lego_trace(wfs, trace, 256, slo_scale=None, admission=False)
    busy = sys2.coordinator.total_busy_time()
    cp = sys2.coordinator.control_plane_time
    emit("s75_control_plane_share", cp * 1e6,
         f"{100*cp/max(busy,1e-9):.1f}% of executor busy time "
         f"({len(trace)} requests, 256 executors)")
    eng = sys2.coordinator.engine
    emit("s75_data_plane", eng.bytes_transferred / 2**20,
         f"transfers={eng.num_transfers};local_hits={eng.num_local_hits}")

    # §8: multi-coordinator sharding — same 256-GPU load split across
    # model-sharing clusters; the (max) per-coordinator control-plane time
    # is the scalability figure
    from repro.core import CoordinatorGroup
    group = CoordinatorGroup(wfs, n_executors=256, admission_enabled=False)
    for t in trace:
        group.submit(t.workflow, inputs=t.inputs, arrival=t.arrival)
    group.run()
    cp_g = group.control_plane_time()
    busy_g = group.total_busy_time()
    emit("s75_sharded_control_plane", cp_g * 1e6,
         f"{group.n_coordinators} coordinators; "
         f"{100*cp_g/max(busy_g,1e-9):.1f}% of busy time "
         f"(vs {100*cp/max(busy,1e-9):.1f}% single-coordinator)")
