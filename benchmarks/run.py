"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` trims the heavy rate
sweeps; ``--only <module>`` runs a single benchmark.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_adaptive,
    bench_admission,
    bench_async_lora,
    bench_burst,
    bench_caching,
    bench_chaos,
    bench_datafetch,
    bench_latency_throughput,
    bench_multitenant,
    bench_overhead,
    bench_parallelism,
    bench_proc_chaos,
    bench_programmability,
    bench_rawspeed,
    bench_scaling,
    bench_sharing,
    bench_slo_scale,
    bench_slo_vs_rate,
    bench_telemetry,
    bench_testbed,
    roofline,
)

ALL = [
    ("fig3_scaling", bench_scaling),
    ("fig3_latency_throughput", bench_latency_throughput),
    ("fig4_sharing", bench_sharing),
    ("fig4_adaptive", bench_adaptive),
    ("fig9_rate", bench_slo_vs_rate),
    ("fig9g_slo_scale", bench_slo_scale),
    ("fig9h_burst", bench_burst),
    ("fig9i_testbed", bench_testbed),
    ("fig10_parallelism", bench_parallelism),
    ("fig10_admission", bench_admission),
    ("fig11_datafetch", bench_datafetch),
    ("table3_programmability", bench_programmability),
    ("s74_caching", bench_caching),
    ("s74_async_lora", bench_async_lora),
    ("s75_overhead", bench_overhead),
    ("s6_chaos", bench_chaos),
    ("s7_proc_chaos", bench_proc_chaos),
    ("multitenant", bench_multitenant),
    ("s8_telemetry", bench_telemetry),
    ("s9_rawspeed", bench_rawspeed),
    ("roofline", roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in ALL:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            if args.quick and name == "fig9_rate":
                mod.run(settings=("s1", "s6"), rates=(1.0, 2.0))
            elif args.quick and name in ("multitenant", "s8_telemetry",
                                         "s9_rawspeed"):
                mod.run(smoke=True)
            else:
                mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
