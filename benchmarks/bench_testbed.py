"""Fig 9 (i): SLO attainment vs testbed size (S6, rate 0.5, SLO 2.0) —
how many GPUs each system needs for 90% attainment."""

from benchmarks.common import emit, run_lego_trace, run_mono_trace
from repro.diffusion import table2_setting
from repro.sim import generate_trace


def run() -> None:
    wfs = table2_setting("s6")
    trace = generate_trace(list(wfs), rate=0.5, duration=240, cv=2.0, seed=19)
    lego_need = None
    s_need = None
    for n in (8, 12, 16, 24, 32):
        lego = run_lego_trace(wfs, trace, n, slo_scale=2.0).slo_attainment()
        s = run_mono_trace(wfs, trace, n, "diffusers-s", 2.0).slo_attainment()
        if lego_need is None and lego >= 0.9:
            lego_need = n
        if s_need is None and s >= 0.9:
            s_need = n
        emit(f"fig9i_testbed[{n}]", n * 1e6, f"lego={lego:.2f};diffusers-s={s:.2f}")
    emit("fig9i_gpu_reduction", (lego_need or 32) * 1e6,
         f"lego_needs={lego_need};diffusers-s_needs={s_need or '>32'};"
         + (f"ratio={s_need/lego_need:.1f}x" if lego_need and s_need else "ratio=>%.1fx" % (32/(lego_need or 32))))
