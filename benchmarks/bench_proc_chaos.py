"""Process-isolated executor plane: overhead and chaos attainment.

Runs the S1 trace (sd3 basic / +C.N.1 / +C.N.2) on REAL worker
processes (multiprocessing spawn + TCP frame transport) and reports:

* ``proc_overhead`` — the honest cost of process isolation on a
  fault-free trace vs the in-process executable plane: serialization
  wall, transport wall vs worker compute, bytes shipped over the
  sockets, and the staging protocol's hit/ship split.
* ``proc_chaos_ratio`` — SLO attainment under a SIGKILL/respawn cadence
  (process-level faults through the ``REPRO_FAULTS`` grammar: workers
  killed mid-RPC, respawned by the supervisor with the measured restart
  wall charged to the revive delay) relative to the fault-free proc
  plane.  Acceptance bar: ratio >= 0.9.
* ``recovery`` — kill -9 the lead worker right after the second segment
  chunk's exec frame is on the wire; the recovered image must be
  BIT-EXACT against the fault-free run.
* the serving-system + transport invariants (exactly-once, no leaks,
  replies == applied + fenced) after every arm.

SLO deadlines come from solo latencies measured on a warmed proc system
(the executable plane's timeline is measured wall, so analytic solos
would not be comparable).  All arms share one on-disk XLA cache so
respawned workers re-pay weight init, not compilation.

CLI: ``python -m benchmarks.bench_proc_chaos [--smoke]``; writes
``BENCH_proc_chaos.json`` at the repo root.  Exits 0 with
``skipped: true`` on sandboxed runners that cannot spawn processes.
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Any, Dict, List, Optional

from benchmarks.common import emit
from benchmarks.emit import write_bench_json

PROC_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_proc_chaos.json")
N_EXECUTORS = 2
SLO_SCALE = 8.0
# deadlines must exceed worst-case single-failure recovery (respawn wall
# + the revived worker's cold first dispatch: weight re-init plus disk
# compile-cache hits) to be meaningful — the toy models' solo latencies
# are milliseconds while a real process restart is seconds, so the grace
# term, reported in the JSON, carries that
SLO_GRACE = 4.0


def _system(workflows, backend, faults=None):
    from repro.core import Scheduler, ServingSystem

    sys_ = ServingSystem(n_executors=N_EXECUTORS, backend=backend,
                         faults=faults)
    sys_.coordinator.scheduler = Scheduler(
        sys_.profiles, use_declared_max_batch=True, segment_chunk=2)
    for t in workflows.values():
        sys_.register(t)
    return sys_


def _measure_solos(workflows, steps: int) -> Dict[str, float]:
    """Solo latency per workflow on a WARMED proc system: the first pass
    pays spawn + compile (discarded), the second is the measured solo."""
    from repro.core import ProcBackend

    solos: Dict[str, float] = {}
    with _system(workflows, ProcBackend()) as sys_:
        co = sys_.coordinator
        for _ in range(2):
            solos.clear()
            for name in workflows:
                t0 = co.now
                r = sys_.submit(name, inputs={"prompt": "warm", "seed": 0},
                                arrival=co.now, steps=steps)
                sys_.run()
                assert r.status == "done", (name, r.status)
                solos[name] = r.completion - t0
    return solos


_PROC_COUNTERS = (
    "n_execs", "transport_seconds", "worker_seconds", "restart_seconds",
    "bytes_tx", "bytes_rx", "bytes_shipped", "staging_hits",
    "staging_ships", "n_fenced", "n_exec_replies", "n_exec_applied",
)


def _proc_snapshot(co) -> Dict[str, float]:
    be = co.backend
    snap = {k: getattr(be, k) for k in _PROC_COUNTERS}
    snap["ser_seconds"] = be.ser_seconds + co.engine.ser_seconds
    snap["n_spawns"] = be.supervisor.n_spawns
    return snap


def _arm(workflows, trace, solos, steps: int, proc: bool,
         fault_spec: Optional[str] = None) -> Dict[str, Any]:
    """One arm = warm pass (same trace, no SLOs, faults detached — pays
    jit compiles and weight init) + measured pass.  Attainment and the
    overhead split are computed over the measured pass only."""
    from repro.core import FaultPlane, LocalBackend, ProcBackend
    from repro.sim import check_invariants

    faults = FaultPlane.from_env(fault_spec) if fault_spec else None
    backend = ProcBackend() if proc else LocalBackend()
    with _system(workflows, backend) as sys_:
        co = sys_.coordinator
        for tr in trace:   # warm pass
            sys_.submit(tr.workflow, inputs=tr.inputs, arrival=tr.arrival,
                        steps=steps)
        sys_.run()
        warm_end = co.now
        if faults is not None:   # chaos armed for the measured pass only
            co.faults = faults
            co.engine.faults = faults
            if proc:
                backend._faults = faults
                backend.supervisor.faults = faults
        snap = _proc_snapshot(co) if proc else {}
        wall0 = time.perf_counter()
        traced = [
            sys_.submit(tr.workflow, inputs=tr.inputs,
                        arrival=warm_end + tr.arrival,
                        slo_seconds=SLO_SCALE * solos[tr.workflow]
                        + SLO_GRACE,
                        steps=steps)
            for tr in trace
        ]
        sys_.run()
        wall = time.perf_counter() - wall0
        errs = check_invariants(co)
        done = [r for r in traced if r.status == "done"]
        lats = sorted(r.latency for r in done)
        p99 = lats[min(len(lats) - 1, int(0.99 * len(lats)))] if lats \
            else float("nan")
        out: Dict[str, Any] = {
            "attainment": sum(1 for r in done if r.attained) / len(traced),
            "p99_latency_s": p99,
            "finished": len(done),
            "rejected": sum(1 for r in traced if r.status == "rejected"),
            "shed": sum(1 for r in traced if r.status == "shed"),
            "requeues": co.n_requeues,
            "worker_deaths": co.n_worker_deaths,
            "revives": sum(e.n_revives for e in co.executors),
            "wall_seconds": wall,
            "faults_injected": faults.counts() if faults is not None else {},
            "invariants_ok": not errs,
            "invariant_errors": errs,
        }
        if proc:
            after = _proc_snapshot(co)
            out["proc"] = {k: round(after[k] - snap[k], 6)
                           if isinstance(after[k], float)
                           else after[k] - snap[k]
                           for k in after}
    return out


def trace_study(smoke: bool = False) -> Dict[str, Any]:
    from repro.diffusion import table2_setting
    from repro.sim import generate_trace

    workflows = table2_setting("s1")
    steps = 3 if smoke else 4
    duration = 6.0 if smoke else 20.0
    trace = generate_trace(list(workflows), rate=1.0, duration=duration,
                           cv=1.0, seed=7)
    solos = _measure_solos(workflows, steps)
    out: Dict[str, Any] = {
        "n_requests": len(trace),
        "steps": steps,
        "slo_scale": SLO_SCALE,
        "slo_grace_s": SLO_GRACE,
        "solo_latency_s": solos,
    }

    out["inproc"] = _arm(workflows, trace, solos, steps, proc=False)
    emit("proc_inproc_baseline", out["inproc"]["attainment"] * 100,
         f"n={len(trace)};wall={out['inproc']['wall_seconds']:.1f}s")

    out["proc"] = _arm(workflows, trace, solos, steps, proc=True)
    p = out["proc"]["proc"]
    compute = max(p["worker_seconds"], 1e-9)
    out["proc_overhead"] = {
        "ser_over_compute": p["ser_seconds"] / compute,
        "transport_over_compute": p["transport_seconds"] / compute,
        "attainment_vs_inproc":
            out["proc"]["attainment"] / out["inproc"]["attainment"]
            if out["inproc"]["attainment"] else 0.0,
    }
    emit("proc_faultfree", out["proc"]["attainment"] * 100,
         f"ser/compute={out['proc_overhead']['ser_over_compute']:.3f};"
         f"transport/compute="
         f"{out['proc_overhead']['transport_over_compute']:.3f};"
         f"shipMB={p['bytes_shipped'] / 1e6:.1f}")

    # kill/revive cadence sized off the fault-free arm's exec count, and
    # built through the REPRO_FAULTS grammar operators would use
    kills = 1 if smoke else 3
    every = max(5, p["n_execs"] // (kills + 1))
    spec = f"kill_every={every},max_kills={kills},seed=7"
    out["kill_spec"] = spec
    out["proc_chaos"] = _arm(workflows, trace, solos, steps, proc=True,
                             fault_spec=spec)
    base = out["proc"]["attainment"]
    ratio = out["proc_chaos"]["attainment"] / base if base else 0.0
    out["proc_chaos_ratio"] = ratio
    out["within_10pct"] = ratio >= 0.9
    emit("proc_kill_revive", out["proc_chaos"]["attainment"] * 100,
         f"ratio={ratio:.3f};kills={out['proc_chaos']['worker_deaths']};"
         f"restart={out['proc_chaos']['proc']['restart_seconds']:.1f}s")
    return out


def recovery_parity(steps: int = 5) -> Dict[str, Any]:
    """kill -9 the lead worker right after the second segment chunk's
    exec frame hits the wire; recovery must be bit-exact."""
    import numpy as np

    from repro.core import FaultPlane, ProcBackend
    from repro.diffusion import make_basic_workflow
    from repro.sim import check_invariants

    def serve(faults):
        wf = make_basic_workflow("sd3")
        with _system({wf.name: wf}, ProcBackend(), faults=faults) as sys_:
            r = sys_.submit(wf.name, inputs={"seed": 0, "prompt": "chaos"},
                            arrival=0.0, steps=steps)
            sys_.run()
            assert r.status == "done", r.status
            img = np.asarray(sys_.coordinator.engine.value_of(
                r.ref_key(r.graph.outputs["image"])))
            be = sys_.coordinator.backend
            seg_idxs = [i for i, (m, _) in enumerate(be.exec_log)
                        if m.startswith("segment:")]
            errs = check_invariants(sys_.coordinator)
            stats = {
                "worker_deaths": sys_.coordinator.n_worker_deaths,
                "restart_seconds": be.restart_seconds,
                "n_fenced": be.n_fenced,
            }
        return img, seg_idxs, errs, stats

    want, seg_idxs, _, _ = serve(None)
    faults = FaultPlane(seed=0, kill_every_execs=seg_idxs[1], max_kills=1)
    got, _, errs, stats = serve(faults)
    bitexact = bool(np.array_equal(got, want))
    out = {
        "bitexact": bitexact,
        "kills": faults.n_kills,
        "invariants_ok": not errs,
        "invariant_errors": errs,
        **stats,
    }
    emit("proc_recovery_bitexact", float(bitexact),
         f"kills={faults.n_kills};"
         f"restart={stats['restart_seconds']:.1f}s")
    return out


def run(smoke: bool = False) -> Dict[str, Any]:
    from repro.core import processes_available

    if not processes_available():
        result: Dict[str, Any] = {"skipped": True,
                                  "reason": "cannot spawn processes"}
        emit("proc_chaos_skipped", 1.0, "sandboxed runner")
    else:
        # one shared on-disk XLA cache for every arm AND every respawned
        # worker (children inherit the env; the supervisor's own cache
        # dir is only a fallback)
        os.environ.setdefault(
            "JAX_COMPILATION_CACHE_DIR",
            tempfile.mkdtemp(prefix="repro-bench-proc-xla-"))
        os.environ.setdefault(
            "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
        os.environ.setdefault(
            "JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "0")
        result = {
            "trace": trace_study(smoke=smoke),
            "recovery": recovery_parity(steps=3 if smoke else 5),
        }
        ok = (result["trace"]["within_10pct"]
              and result["recovery"]["bitexact"]
              and result["trace"]["inproc"]["invariants_ok"]
              and result["trace"]["proc"]["invariants_ok"]
              and result["trace"]["proc_chaos"]["invariants_ok"]
              and result["recovery"]["invariants_ok"])
        result["acceptance_ok"] = ok
        emit("proc_chaos_acceptance", float(ok),
             f"ratio={result['trace']['proc_chaos_ratio']:.3f};"
             f"bitexact={result['recovery']['bitexact']}")
    write_bench_json("proc_chaos", result, path=PROC_JSON,
                     gates={"acceptance_ok": result.get("acceptance_ok")})
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace, single kill (CI liveness)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
