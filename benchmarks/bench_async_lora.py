"""§7.4: asynchronous LoRA loading — loading overhead visible to the
request, async (Katz-style compiler pass) vs synchronous fetch."""

from benchmarks.common import emit, run_lego_trace
from repro.core import GraphCompiler, ServingSystem
from repro.core.passes import AsyncLoRAPass, InlineTrivialPass, JitCompilePass
from repro.diffusion import make_basic_workflow, make_lora_workflow
from repro.sim import generate_trace


def _solo_latency(extra_async: bool) -> float:
    passes = [InlineTrivialPass()] + ([AsyncLoRAPass()] if extra_async else [])         + [JitCompilePass()]
    sys_ = ServingSystem(n_executors=2)
    sys_.registry.compiler = GraphCompiler(passes)
    wf = make_lora_workflow("sdxl", "papercut")
    sys_.register(wf)
    r = sys_.submit(wf.name, inputs={"seed": 1, "prompt": "papercut fox"},
                    arrival=0.0, slo_seconds=None)
    sys_.run()
    return r.latency


def run() -> None:
    base_sys = ServingSystem(n_executors=2)
    base = make_basic_workflow("sdxl")
    base_sys.register(base)
    r0 = base_sys.submit(base.name, inputs={"seed": 1, "prompt": "x"})
    base_sys.run()
    t_plain = r0.latency
    t_sync = _solo_latency(False)
    t_async = _solo_latency(True)
    emit("s74_lora_sync_overhead", (t_sync - t_plain) * 1e6,
         f"{t_sync - t_plain:.2f}s (paper: ~0.5s)")
    emit("s74_lora_async_overhead", (t_async - t_plain) * 1e6,
         f"{t_async - t_plain:.3f}s (paper: ~0.05s)")
