"""§Roofline: three-term roofline per (arch x shape) from the dry-run
artifacts (dryrun_results.json).  See EXPERIMENTS.md for the narrative."""

import json
import os

from benchmarks.common import emit

CHIPS = 256
PEAK = 197e12          # bf16 FLOP/s per v5e chip
HBM = 819e9            # B/s
ICI = 50e9             # B/s per link

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


def terms(rec):
    """Three roofline terms in seconds.  Dynamic HLO costs are
    PER-PARTITION (post-SPMD module x trip counts), so each term divides
    by a single chip's capability."""
    flops = rec.get("hlo_flops", 0.0)
    byt = rec.get("hlo_bytes", 0.0)
    coll = rec.get("collectives", {}).get("total", 0.0)
    if rec.get("per_partition"):
        t_c = flops / PEAK
        t_m = byt / HBM
        t_x = coll / ICI
    else:
        t_c = flops / (CHIPS * PEAK)
        t_m = byt / (CHIPS * HBM)
        t_x = coll / (CHIPS * ICI)
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    return t_c, t_m, t_x, dom


def model_flops(rec):
    shape_tokens = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
                    "decode_32k": 128, "long_500k": 1}
    tok = shape_tokens[rec["shape"]]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * rec["active_params"] * tok


def run() -> None:
    if not os.path.exists(RESULTS):
        emit("roofline", 0.0, "dryrun_results.json missing - run the dry-run")
        return
    data = json.load(open(RESULTS))
    for rec in data:
        if not rec.get("ok") or rec.get("mesh") != "16x16":
            continue
        t_c, t_m, t_x, dom = terms(rec)
        mf = model_flops(rec)
        total_hlo = rec.get("hlo_flops", 0.0) * (
            CHIPS if rec.get("per_partition") else 1)
        ratio = mf / total_hlo if total_hlo else 0.0
        peak = (rec.get("bytes_per_device", {}) or {}).get("peak") or 0
        emit(f"roofline[{rec['arch']},{rec['shape']}]",
             max(t_c, t_m, t_x) * 1e6,
             f"compute={t_c:.2e}s;memory={t_m:.2e}s;coll={t_x:.2e}s;"
             f"dominant={dom};useful_flops_ratio={ratio:.2f};"
             f"peak_mem={peak/2**30:.1f}GiB")
