"""Fig 4-left + §7.3: cross-workflow model sharing on 2 GPUs.

A (basic, +ControlNet) workflow pair shares text encoder + backbone + VAE.
Compare micro-serving (shared replicas) against isolated monolithic
replicas: request latency and resident GPU memory."""

from benchmarks.common import emit, run_lego_trace, run_mono_trace
from repro.diffusion import FAMILIES, ModelSet, make_basic_workflow, make_controlnet_workflow
from repro.sim import generate_trace, mean_latency


def run() -> None:
    for fam in ("sd3", "flux-dev"):
        ms = ModelSet(FAMILIES[fam])
        wfs = {}
        for t in (make_basic_workflow(fam, ms), make_controlnet_workflow(fam, 1, ms)):
            wfs[t.name] = t
        trace = generate_trace(list(wfs), rate=0.6, duration=180, cv=1.5, seed=5)
        lego = run_lego_trace(wfs, trace, 2, slo_scale=None, admission=False)
        mono = run_mono_trace(wfs, trace, 2, "diffusers", slo_scale=None,
                              admission=False)
        l_lat = lego.mean_latency()
        m_lat = sum((r.latency or 0) for r in mono.records if r.latency) / max(
            1, sum(1 for r in mono.records if r.latency))
        emit(f"fig4_sharing_latency[{fam}]", l_lat * 1e6,
             f"reduction={100*(1-l_lat/m_lat):.0f}%")
        # memory: bytes of DISTINCT models lego keeps resident to serve all
        # variants vs the per-workflow replicas monolithic serving binds
        distinct = {}
        for e in lego.executors:
            for mid, b in e.loaded.items():
                distinct[mid] = b
        lego_mem = sum(distinct.values())
        mono_mem = sum(s.footprint_bytes for s in mono.specs.values())
        emit(f"fig4_sharing_memory[{fam}]", lego_mem / 2**20,
             f"reduction={100*(1-lego_mem/mono_mem):.0f}%")
        # §7.3: LoRA patch swap vs fresh model load
        hw = lego.profiles.hw
        lora_bytes = 886 * 2**20
        swap = hw.patch_swap_time + lora_bytes / hw.remote_bw * 0
        load = FAMILIES[fam].backbone_bytes() / hw.host_load_bw
        emit(f"s73_patch_swap[{fam}]", swap * 1e6,
             f"saves={FAMILIES[fam].backbone_bytes()/2**30:.1f}GiB+{load:.2f}s")
