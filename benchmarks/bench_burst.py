"""Fig 9 (h): SLO attainment vs traffic burstiness CV (S6, 16 GPUs),
plus the per-model autoscaling study: a mean-provisioned fleet with a
cold reserve pool vs the same fleet fixed, swept over burst multipliers."""

from benchmarks.common import emit, run_lego_trace, run_mono_trace, serving_horizon
from repro.diffusion import table2_setting
from repro.sim import diurnal_trace, generate_trace, mean_fleet_size


def run() -> None:
    wfs = table2_setting("s6")
    last_lego_cv = 0
    last_s_cv = 0
    for cv in (1, 2, 4, 8):
        trace = generate_trace(list(wfs), rate=0.6, duration=240, cv=cv, seed=17)
        lego = run_lego_trace(wfs, trace, 16, slo_scale=2.0).slo_attainment()
        s = run_mono_trace(wfs, trace, 16, "diffusers-s", 2.0).slo_attainment()
        if lego >= 0.75:
            last_lego_cv = cv
        if s >= 0.75:
            last_s_cv = cv
        emit(f"fig9h_cv[{cv}]", cv * 1e6, f"lego={lego:.2f};diffusers-s={s:.2f}")
    emit("fig9h_burst_tolerance", last_lego_cv * 1e6,
         f"lego_cv={last_lego_cv};baseline_cv={max(last_s_cv,1)};"
         f"ratio={last_lego_cv/max(last_s_cv,1):.0f}x")
    autoscaler_study(wfs)


def autoscaler_study(wfs, base: int = 8, reserve: int = 8,
                     factors=(2, 4, 8), target: float = 0.9) -> None:
    """Fixed fleet provisioned for the mean rate vs the same base fleet
    with a reserve pool the per-model autoscaler may activate.  The
    sustained *burst multiplier* (highest diurnal burst factor holding
    >= ``target`` attainment) is the paper's 8x-burst-tolerance axis."""
    best_fixed = 0
    best_auto = 0
    for factor in factors:
        trace = diurnal_trace(list(wfs), base_rate=0.4, duration=180,
                              burst_factor=factor, cv=2.0, seed=23)
        fixed = run_lego_trace(wfs, trace, base, slo_scale=2.0)
        auto = run_lego_trace(wfs, trace, base, slo_scale=2.0,
                              autoscaler=True, reserve_executors=reserve)
        fa = fixed.slo_attainment()
        aa = auto.slo_attainment()
        if fa >= target:
            best_fixed = factor
        if aa >= target:
            best_auto = factor
        c = auto.coordinator
        fleet = mean_fleet_size(c.fleet_log, serving_horizon(c), base)
        emit(f"fig9h_autoscale[x{factor}]", factor * 1e6,
             f"auto={aa:.2f};fixed={fa:.2f};mean_fleet={fleet:.1f};"
             f"ups={len(c.scale_actions('scale_up'))};"
             f"downs={len(c.scale_actions('scale_down'))}")
    emit("fig9h_autoscale_burst_multiplier", best_auto * 1e6,
         f"auto_x={best_auto};fixed_x={max(best_fixed, 1)};"
         f"ratio={best_auto / max(best_fixed, 1):.0f}x")
