"""Fig 9 (h): SLO attainment vs traffic burstiness CV (S6, 16 GPUs)."""

from benchmarks.common import emit, run_lego_trace, run_mono_trace
from repro.diffusion import table2_setting
from repro.sim import generate_trace


def run() -> None:
    wfs = table2_setting("s6")
    last_lego_cv = 0
    last_s_cv = 0
    for cv in (1, 2, 4, 8):
        trace = generate_trace(list(wfs), rate=0.6, duration=240, cv=cv, seed=17)
        lego = run_lego_trace(wfs, trace, 16, slo_scale=2.0).slo_attainment()
        s = run_mono_trace(wfs, trace, 16, "diffusers-s", 2.0).slo_attainment()
        if lego >= 0.75:
            last_lego_cv = cv
        if s >= 0.75:
            last_s_cv = cv
        emit(f"fig9h_cv[{cv}]", cv * 1e6, f"lego={lego:.2f};diffusers-s={s:.2f}")
    emit("fig9h_burst_tolerance", last_lego_cv * 1e6,
         f"lego_cv={last_lego_cv};baseline_cv={max(last_s_cv,1)};"
         f"ratio={last_lego_cv/max(last_s_cv,1):.0f}x")
