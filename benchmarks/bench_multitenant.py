"""Multi-tenant LoRA serving: unfolded grouped forwards vs fold-per-placement.

N tenants share one sd3 base model, each with a distinct LoRA adapter;
traffic is perfectly mixed (round-robin across tenants, all arrivals
concurrent).  Two arms, identical byte budget for adapter-derived device
state:

* ``fold`` — the legacy path (``Scheduler(multilora=False)``): batches
  partition by patch set, every placement folds adapter deltas into a
  full copy of the base parameters, held in the bounded ``_folded`` LRU.
  At high N the per-placement copies exceed the budget and the arm pays
  fold churn on every request.
* ``unfolded`` — the grouped route (``Scheduler(multilora=True)``):
  mixed batches execute as ONE forward via the grouped LoRA kernel form
  (stacked A/B factors + per-row adapter indices); the only per-tenant
  device state is the decoded factors in the :class:`AdapterPool`.

Throughput is measured on the SYSTEM TIMELINE — the executable plane's
hybrid clock (runtime ``_dispatch``): real measured forward/fold wall
plus the modeled data-fetch and ``patch_swap_time`` terms that charge
placement churn at real model scale.  Toy-scale CPU wall alone cannot
represent a fold's true cost (copying a full parameter set vs a 36x
smaller factor pair), so raw wall seconds are reported alongside for
transparency but the img/s figures come from the timeline.

Reported per N (sweep 1 -> 256; ``--smoke`` stops at 64): images/s and
resident adapter-state bytes per arm.  Acceptance bar (ISSUE 8): the
unfolded arm sustains >= 1.3x the fold arm's img/s at N=64.

CLI: ``python -m benchmarks.bench_multitenant [--smoke]``; writes
``BENCH_multitenant.json`` at the repo root.
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict, List

from benchmarks.common import emit
from benchmarks.emit import write_bench_json

OUT_JSON = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_multitenant.json")

# one budget for BOTH arms' adapter-derived device state (folded copies
# there, decoded factors here): ~12 toy-scale folded placements fit, all
# 256 tenants' factors fit — the residency asymmetry under test
STATE_BUDGET = 16 * 2**20
STEPS = 2


def _system(n_tenants: int, multilora: bool):
    from repro.core import GraphCompiler, LocalBackend, Scheduler, ServingSystem
    from repro.core.passes import (
        InlineTrivialPass,
        JitCompilePass,
        SegmentFusionPass,
    )
    from repro.core.registry import WorkflowRegistry
    from repro.diffusion import FAMILIES, ModelSet, make_lora_workflow

    be = LocalBackend(folded_budget_bytes=STATE_BUDGET,
                      adapter_pool_bytes=STATE_BUDGET)
    sys_ = ServingSystem(n_executors=1, backend=be)
    # deterministic adapter semantics arm-to-arm: no AsyncLoRAPass, so
    # every step of every request is patched in both arms
    sys_.registry = WorkflowRegistry(GraphCompiler(
        [InlineTrivialPass(), SegmentFusionPass(), JitCompilePass()]))
    sys_.coordinator.scheduler = Scheduler(
        sys_.profiles, use_declared_max_batch=True, multilora=multilora)
    ms = ModelSet(FAMILIES["sd3"])
    for i in range(n_tenants):
        sys_.register(make_lora_workflow("sd3", f"t{i}", ms))
    return sys_, be


def _wave(sys_, n_tenants: int) -> Dict[str, float]:
    """One request per tenant, all concurrent; returns the timeline and
    wall seconds from first submit to last completion."""
    co = sys_.coordinator
    v0 = co.now
    t0 = time.perf_counter()
    reqs = [sys_.submit(f"sd3:lora:t{i}",
                        inputs={"seed": i, "prompt": "tenant traffic"},
                        arrival=co.now, steps=STEPS)
            for i in range(n_tenants)]
    sys_.run()
    wall = time.perf_counter() - t0
    bad = [r.status for r in reqs if r.status != "done"]
    assert not bad, f"wave left requests unfinished: {bad}"
    return {"timeline": co.now - v0, "wall": wall}


def _run_arm(n_tenants: int, multilora: bool, waves: int) -> Dict[str, Any]:
    sys_, be = _system(n_tenants, multilora)
    _wave(sys_, n_tenants)                      # warmup: compile + loads
    runs = [_wave(sys_, n_tenants) for _ in range(waves)]
    timeline = sum(r["timeline"] for r in runs)
    wall = sum(r["wall"] for r in runs)
    imgs = n_tenants * waves
    pool = be.adapter_pool
    return {
        "imgs_per_s": imgs / timeline,
        "timeline_s": timeline,
        "wall_imgs_per_s": imgs / wall,
        "wall_s": wall,
        "folded_resident_bytes": be.folded_resident_bytes,
        "folded_evictions": be.folded_evictions,
        "adapter_pool_bytes": pool.resident_bytes,
        "adapter_pool_evictions": pool.evictions,
        "multilora_forwards": be.multilora_forwards,
        "forwards": len([f for f in be.forward_log
                         if not f[0].startswith("evict:")]),
    }


def run(smoke: bool = False) -> Dict[str, Any]:
    sweep_n = [1, 4, 16, 64] if smoke else [1, 4, 16, 64, 256]
    waves = 1 if smoke else 2
    rows: List[Dict[str, Any]] = []
    for n in sweep_n:
        fold = _run_arm(n, multilora=False, waves=waves)
        unf = _run_arm(n, multilora=True, waves=waves)
        speedup = unf["imgs_per_s"] / fold["imgs_per_s"]
        rows.append({"n_adapters": n, "fold": fold, "unfolded": unf,
                     "speedup": speedup})
        emit(f"multitenant[N={n}]", 1e6 / unf["imgs_per_s"],
             f"unfolded={unf['imgs_per_s']:.2f}img/s "
             f"fold={fold['imgs_per_s']:.2f}img/s speedup={speedup:.2f}x "
             f"state={unf['adapter_pool_bytes']/2**20:.2f}MiB"
             f"/{fold['folded_resident_bytes']/2**20:.2f}MiB")
        # the pool must stay inside its budget at every N
        assert unf["adapter_pool_bytes"] <= STATE_BUDGET
        assert fold["folded_resident_bytes"] <= STATE_BUDGET

    at64 = next(r for r in rows if r["n_adapters"] == 64)
    result = {
        "smoke": smoke,
        "steps_per_request": STEPS,
        "state_budget_bytes": STATE_BUDGET,
        "sweep": rows,
        "n64_speedup": at64["speedup"],
        "pass_1p3x": at64["speedup"] >= 1.3,
    }
    write_bench_json("multitenant", result, path=OUT_JSON,
                     gates={"pass_1p3x": result["pass_1p3x"]})
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short sweep (N<=64, one measured wave)")
    args = ap.parse_args()
    result = run(smoke=args.smoke)
    print(f"n64_speedup={result['n64_speedup']:.2f}x "
          f"pass_1p3x={result['pass_1p3x']}")


if __name__ == "__main__":
    main()
