"""§7.4: Nirvana-style approximate caching — speedup at 20%/40% skipped
denoising computation (compiler pass rewrite, no workflow change)."""

from benchmarks.common import emit
from repro.core import GraphCompiler, ServingSystem
from repro.core.passes import ApproximateCachingPass, default_passes
from repro.core.admission import critical_path_seconds
from repro.diffusion import ApproxCache, make_basic_workflow
from repro.diffusion.config import FAMILIES


def run() -> None:
    fam = "sdxl"
    base_wf = make_basic_workflow(fam)
    base_sys = ServingSystem(n_executors=1)
    base_sys.register(base_wf)
    t0 = base_sys.solo_latency(f"{fam}:basic")
    for frac in (0.2, 0.4):
        cache = ApproxCache(similarity_threshold=0.0)
        cache.insert("warm prompt", int(frac * FAMILIES[fam].denoise_steps), None)
        sys_ = ServingSystem(
            n_executors=1,
            extra_passes=[ApproximateCachingPass(
                cache, backbone_model_id=f"backbone:{fam}",
                skip_fraction=frac)],
        )
        wf = make_basic_workflow(fam)
        sys_.register(wf)
        t = sys_.solo_latency(f"{fam}:basic")
        emit(f"s74_approx_cache[skip={int(frac*100)}%]", t * 1e6,
             f"speedup={t0/t:.2f}x (paper: {1.17 if frac == 0.2 else 1.42}x)")
